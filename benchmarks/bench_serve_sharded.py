"""Sharded executor over the ('kv', 'hd') serve mesh: kernels LIVE.

Before this PR the mesh mode silently swapped a kernel-built model for
its jnp twin (every Pallas kernel assumed the full single-device pool
view), so sharded serving forfeited the paged-prefill kernel's
bytes-gathered win.  Now the executor wraps the kernels in shard_map and
dispatches them on each device's local pool slice, and this benchmark is
the gate that keeps them live.

It preloads a shared prefix and drives a forked-prefix workload (COW
tail-page copies + batched continuation prefill — the dispatch whose
gather volume the PR 2 kernel collapsed) through THREE engines built
from the same kernel model:

  * ``single``      — no mesh, Pallas kernels;
  * ``sharded``     — >1-device mesh, Pallas kernels through shard_map;
  * ``sharded_ref`` — same mesh, ``ServeConfig.use_ref_path=True``: the
    explicit jnp escape hatch (``--no-kernels``), kept as the baseline
    that shows what the mesh used to cost.

Reported per engine: decode tok/s (informational on CPU-forced host
devices), kernel vs ref-path dispatch counts, and ``prefill_bytes_gathered``
— the modeled KV bytes the continuation-prefill attention reads (kernel:
only pages the banded [start, start+chunk) window touches; ref path: every
``max_pages_per_seq`` page of every row).  ``benchmarks/run.py --only
sharded`` gates on token identity single vs sharded, kernels actually live
(``kernel_dispatches > 0`` and ``ref_path_dispatches == 0``), and the
sharded engine gathering STRICTLY fewer prefill bytes than the ref-path
engine; wall-clock is never gated (CPU collectives are emulation).

With a single visible device the mesh degrades to 1x1 and the
``sharded`` engine still runs the shard_map-free kernel path; the CI
``multidevice`` job forces 8 host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

# same driver and jit-cache warmer as the seed-vs-split benchmark
from benchmarks.bench_serve_throughput import _drive, _warm


def _fork_workload(cfg, n=5, seed=17, max_new=10):
    from repro.serve import ServeRequest

    rng = np.random.default_rng(seed)
    return [
        ServeRequest(req_id=i,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         size=int(rng.integers(4, 10))
                                         ).astype(np.int32),
                     max_new_tokens=max_new, share_prefix=True)
        for i in range(n)
    ]


def run() -> tuple[list[str], dict]:
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_host_serve_mesh
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False, use_kernels=True)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_serve_mesh(cfg.num_kv_heads, cfg.head_dim)
    print(f"serve mesh {dict(mesh.shape)}: {mesh.size} of "
          f"{jax.device_count()} visible devices")

    serve_cfg = ServeConfig(page_size=4, num_pages=32, max_pages_per_seq=16,
                            max_batch=3)
    prefix = np.random.default_rng(5).integers(
        0, cfg.vocab_size, size=10).astype(np.int32)
    reqs = _fork_workload(cfg)

    plans = (
        ("single", {}, False),
        ("sharded", {"mesh": mesh}, False),
        ("sharded_ref", {"mesh": mesh}, True),
    )
    results = {}
    outs = {}
    for name, kw, ref_path in plans:
        scfg = dataclasses.replace(serve_cfg, use_ref_path=ref_path)
        _warm(functools.partial(Engine, **kw), model, params, cfg, scfg)
        eng = Engine(model, params, scfg, **kw)
        eng.preload_prefix(prefix)
        done, wall = _drive(eng, reqs)
        eng.executor.check_sharding_invariants()
        outs[name] = {i: [int(x) for x in done[i].output] for i in done}
        c = eng.counters
        toks = c.get("decode_tokens")
        results[name] = dict(
            wall=wall,
            decode_tok_per_s=toks / max(c.seconds("decode"), 1e-9),
            host_syncs_per_tok=c.ratio("host_syncs", "decode_tokens"),
            ptab_syncs_per_tok=c.ratio("ptab_syncs", "decode_tokens"),
            mean_horizon=(c.get("decode_horizon")
                          / max(c.get("decode_dispatches"), 1)),
            forked_admissions=c.get("forked_admissions"),
            kernel_dispatches=c.get("kernel_dispatches"),
            ref_path_dispatches=c.get("ref_path_dispatches"),
            prefill_bytes_gathered=c.get("prefill_bytes_gathered"),
        )
        r = results[name]
        print(f"{name:>11}: {r['decode_tok_per_s']:.1f} decode tok/s, "
              f"{r['kernel_dispatches']} kernel / "
              f"{r['ref_path_dispatches']} ref-path dispatches, "
              f"{r['forked_admissions']} forked admissions, "
              f"{r['prefill_bytes_gathered']} B prefill KV gathered")

    single, shard, ref = (results["single"], results["sharded"],
                          results["sharded_ref"])
    token_identical = outs["single"] == outs["sharded"]
    counters_identical = all(
        single[k] == shard[k]
        for k in ("host_syncs_per_tok", "ptab_syncs_per_tok", "mean_horizon",
                  "forked_admissions", "kernel_dispatches",
                  "prefill_bytes_gathered")
    )
    kernels_live = (shard["kernel_dispatches"] > 0
                    and shard["ref_path_dispatches"] == 0
                    and single["ref_path_dispatches"] == 0)
    bytes_win = (shard["prefill_bytes_gathered"]
                 < ref["prefill_bytes_gathered"]
                 if shard["forked_admissions"] > 0 else False)
    ratio = (ref["prefill_bytes_gathered"]
             / max(shard["prefill_bytes_gathered"], 1))
    print(f"sharded outputs token-identical to single-device kernels: "
          f"{token_identical}; counters identical: {counters_identical}")
    print(f"kernels live on the mesh: {kernels_live}; prefill KV gather "
          f"kernel vs ref path: {shard['prefill_bytes_gathered']} B vs "
          f"{ref['prefill_bytes_gathered']} B ({ratio:.2f}x fewer)")

    metrics = {
        "mesh_devices": int(mesh.size),
        "visible_devices": int(jax.device_count()),
        "token_identical": bool(token_identical),
        "counters_identical": bool(counters_identical),
        "kernels_live": bool(kernels_live),
        "bytes_win": bool(bytes_win),
        "prefill_bytes_gathered_kernel": int(shard["prefill_bytes_gathered"]),
        "prefill_bytes_gathered_ref": int(ref["prefill_bytes_gathered"]),
        "ref_path_dispatches": int(shard["ref_path_dispatches"]),
        "kernel_dispatches": int(shard["kernel_dispatches"]),
        "single": single,
        "sharded": shard,
        "sharded_ref": ref,
    }
    csv = [
        f"serve_sharded_mesh_devices,0,{mesh.size}",
        f"serve_sharded_token_identical,0,{int(token_identical)}",
        f"serve_sharded_kernels_live,0,{int(kernels_live)}",
        f"serve_sharded_kernel_dispatches,0,{shard['kernel_dispatches']}",
        f"serve_sharded_ref_path_dispatches,0,"
        f"{shard['ref_path_dispatches']}",
        f"serve_sharded_prefill_bytes_gathered,0,"
        f"{shard['prefill_bytes_gathered']}",
        f"serve_sharded_prefill_bytes_gathered_ref,0,"
        f"{ref['prefill_bytes_gathered']}",
        f"serve_sharded_decode_tok_per_s,0,"
        f"{shard['decode_tok_per_s']:.2f}",
    ]
    return csv, metrics


def main() -> list[str]:
    csv, _ = run()
    return csv


if __name__ == "__main__":
    main()
