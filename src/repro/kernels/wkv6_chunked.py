"""Chunk-parallel WKV-6 Pallas kernel (flash-linear-attention formulation).

The §Perf cell-C analysis (EXPERIMENTS.md) showed the XLA-native
chunked-matmul WKV is still memory-bound: the per-chunk decay tensor
``exp(cum_i - cum_j)`` ([C, C, N]) and the running state round-trip HBM every
chunk.  This kernel is the TPU-native fix: grid ``(BH, T/C)`` with the
``[N, N]`` state AND all chunk-local tensors resident in VMEM scratch — HBM
traffic collapses to the r/k/v/w/o streams.

Math per chunk (all in f32, exponents <= 0 by construction):
    cum       = cumsum(log w)              (inclusive)
    a_in[i]   = exp(cum[i-1])              (decay from chunk start, excl.)
    o_i       = (r_i * a_in[i]) @ S
              + sum_{j<i} (r_i . k_j) exp(cum[i-1] - cum[j]) v_j
              + (r_i . k_i * u) v_i
    S         = exp(cum[C-1]) * S + sum_j exp(cum[C-1] - cum[j]) k_j v_j^T

Validated in interpret mode against ``ref.wkv6_ref`` (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import should_interpret
from repro.kernels import common


def _wkv6_chunked_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                         o_ref, s_out_ref, s_ref, *, chunk: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(s_ref.dtype)

    rr = r_ref[0].astype(jnp.float32)          # [C, N]
    kk = k_ref[0].astype(jnp.float32)
    vv = v_ref[0].astype(jnp.float32)
    ww = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # [N]
    s = s_ref[...]                             # [N, N]

    logw = jnp.log(jnp.maximum(ww, 1e-38))
    cum = jnp.cumsum(logw, axis=0)             # [C, N] inclusive
    cum_excl = cum - logw
    a_in = jnp.exp(cum_excl)                   # [C, N]

    # inter-chunk: (r * a_in) @ S                     -> [C, N]
    o = jax.lax.dot_general(
        rr * a_in, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # intra-chunk: att[i, j] = sum_n r_i k_j exp(cum_excl_i - cum_j), j < i
    delta = cum_excl[:, None, :] - cum[None, :, :]        # [C, C, N]
    mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
    delta = jnp.where(mask[:, :, None], delta, -jnp.inf)
    att = jnp.einsum("in,jn,ijn->ij", rr, kk, jnp.exp(delta))
    o = o + jax.lax.dot_general(
        att, vv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # diagonal bonus
    o = o + (rr * u[None, :] * kk).sum(axis=1, keepdims=True) * vv
    o_ref[0] = o.astype(o_ref.dtype)

    # state update
    d_c = jnp.exp(cum[-1, :])                  # [N]
    tail = jnp.exp(cum[-1:, :] - cum)          # [C, N]
    s_ref[...] = d_c[:, None] * s + jax.lax.dot_general(
        (kk * tail), vv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _store():
        s_out_ref[0] = s_ref[...].astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked(
    r: jax.Array,   # [BH, T, N]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,   # [BH, N]
    initial_state: jax.Array | None = None,  # [BH, N, N] f32
    *,
    chunk: int = 32,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel WKV-6. Returns (o [BH, T, N], final_state)."""
    if interpret is None:
        interpret = should_interpret()
    bh, t, n = r.shape
    assert t % chunk == 0, (t, chunk)
    if initial_state is None:
        initial_state = jnp.zeros((bh, n, n), jnp.float32)
    return pl.pallas_call(
        functools.partial(_wkv6_chunked_kernel, chunk=chunk),
        out_shape=(
            jax.ShapeDtypeStruct((bh, t, n), r.dtype),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ),
        grid=(bh, t // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, n), lambda b, i: (b, 0)),
            pl.BlockSpec((1, n, n), lambda b, i: (b, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, n, n), lambda b, i: (b, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u, initial_state)
