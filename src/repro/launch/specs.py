"""Dry-run case builders: ShapeDtypeStruct inputs + jitted step functions.

One case per (architecture x input-shape x mesh).  No allocation ever
happens here — params/optimizer shapes come from ``jax.eval_shape`` over the
real init, batches and serving state are ShapeDtypeStructs, and the returned
``jit``-wrapped function is only ``.lower().compile()``d.

Serving topology (DESIGN.md §3): serve trees carry a leading data-group axis
``G`` (= the mesh's data size when the global batch divides it, else 1).
Each group owns its own page pool and page table — attention gathers stay
group-local under SPMD (no cross-data collectives for KV), which is how a
real multi-replica serving deployment shards.  The per-group model call is
``jax.vmap`` over G.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import dp_axes
from repro.launch.sharding import (
    batch_shardings,
    make_shard_hook,
    opt_shardings,
    param_shardings,
)
from repro.models import (
    HybridState,
    PagedKVState,
    RecurrentState,
    build_model,
)
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim import AdamWConfig, adamw_init
from repro.train import make_train_step

PAGE_SIZE = 16
N_VIS = 256  # stub vision prefix length


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


@dataclasses.dataclass
class DryRunCase:
    arch: str
    shape: str
    kind: str                       # train | prefill | decode
    fn: Callable                    # jitted, ready to .lower(*args)
    args: tuple                     # ShapeDtypeStructs
    model_flops_per_step: float     # 6·N·D (train) / 2·N per token (serve)

    def lower(self):
        return self.fn.lower(*self.args)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]


def _group(mesh, global_batch: int) -> tuple[int, int, tuple]:
    """(G, per-group batch, group axes) for serving trees.

    Groups span (pod x data) so multi-pod serving shards the KV pools over
    both axes; falls back to data-only, then to a single replicated group.
    """
    for axes in (("pod", "data"), ("data",)):
        if not all(a in mesh.axis_names for a in axes):
            continue
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if global_batch % n == 0 and global_batch >= n:
            return n, global_batch // n, axes
    return 1, global_batch, ()


def skip_reason(arch: str, shape_name: str) -> str | None:
    """DESIGN.md §4: long_500k only for sub-quadratic archs."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: long_500k requires sub-quadratic "
                "attention (DESIGN.md §4)")
    return None


# ---------------------------------------------------------------------------
# train case
# ---------------------------------------------------------------------------


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "audio" and cfg.num_codebooks > 1:
        return {
            "tokens": sds((b, s, cfg.num_codebooks), jnp.int32),
            "labels": sds((b, s, cfg.num_codebooks), jnp.int32),
            "mask": sds((b, s), jnp.float32),
        }
    batch = {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
        "mask": sds((b, s), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["positions"] = sds((3, b, s), jnp.int32)
        batch["vision_embeds"] = sds((b, N_VIS, cfg.d_model), _dtype(cfg))
    return batch


VARIANTS: dict[str, dict] = {
    # §Perf iteration variants (EXPERIMENTS.md): model-construction kwargs
    "wkv_chunked": {"tm_impl": "chunked_matmul"},       # cell C
    "remat_dots": {"remat_policy": "dots"},             # cell B
    "kv_int8": {"kv_dtype": "int8"},                    # cell A
}


def build_train_case(arch: str, shape_name: str, mesh,
                     variant: str | None = None) -> DryRunCase:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(
        cfg, use_kernels=False, remat=True, shard=make_shard_hook(mesh),
        **(VARIANTS.get(variant, {}) if variant else {}),
    )
    # 100B+-class models: bf16 moments (halves optimizer memory; DESIGN §3)
    moments = "bfloat16" if cfg.param_count() > 100e9 else "float32"
    opt_cfg = AdamWConfig(moment_dtype=moments)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt_shape = jax.eval_shape(
        lambda p: adamw_init(p, opt_cfg.moment_dtype), params_shape
    )
    batch_shape = train_batch_specs(cfg, shape)

    p_sh = param_shardings(params_shape, mesh)
    o_sh = opt_shardings(params_shape, mesh)
    b_sh = batch_shardings(batch_shape, mesh)

    step = make_train_step(model, opt_cfg, donate=True,
                           grad_shardings=p_sh)
    # re-wrap with explicit shardings (make_train_step jits unsharded)
    inner = step.__wrapped__ if hasattr(step, "__wrapped__") else step
    fn = jax.jit(
        inner,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
    return DryRunCase(
        arch=arch, shape=shape_name, kind="train",
        fn=fn, args=(params_shape, opt_shape, batch_shape),
        model_flops_per_step=6.0 * cfg.active_param_count()
        * shape.global_batch * shape.seq_len,
    )


# ---------------------------------------------------------------------------
# serve cases
# ---------------------------------------------------------------------------


def _paged_state_specs(cfg: ModelConfig, g: int, b: int, seq_len: int,
                       frames_per_group: int, max_pages: int,
                       kv_dtype=None):
    dt = kv_dtype if kv_dtype is not None else _dtype(cfg)
    pool = sds(
        (g, cfg.num_layers, frames_per_group, PAGE_SIZE, cfg.num_kv_heads,
         cfg.head_dim), dt,
    )
    return PagedKVState(
        k_pools=pool,
        v_pools=pool,
        page_table=sds((g, b, max_pages), jnp.int32),
        seq_lens=sds((g, b), jnp.int32),
    )


def _ns(mesh, *spec):
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(*spec))


def executor_state_shardings(mesh, num_kv_heads: int, head_dim: int) -> dict:
    """Serving-view shardings for the :class:`repro.serve.executor.Executor`'s
    persistent device state on a ('kv', 'hd') mesh.

    The executor's pools are ``[L, P, page, Hkv, hd]`` (no leading serve
    group: one engine = one replica; multi-replica is the scheduler's seam,
    see ROADMAP).  They shard jointly over (kv, hd) exactly like the
    dry-run serving view above — each axis degrades to replicated when its
    dim does not divide the mesh extent — while the page table, token /
    position operands and sampled-token outputs replicate: they are the
    satp analogue every shard must read coherently.

    The per-dim axis choice is delegated to
    :func:`repro.launch.mesh.kv_partition_axes` so the shard_map kernel
    dispatch in ``kernels.ops`` (which must hand each device exactly its
    committed pool slice) can never disagree with the executor layout.
    """
    from repro.launch.mesh import kv_partition_axes

    kv_ax, hd_ax = kv_partition_axes(mesh, num_kv_heads, head_dim)
    return {
        "pool": _ns(mesh, None, None, None, kv_ax, hd_ax),
        "replicated": _ns(mesh),
    }


def build_serve_case(arch: str, shape_name: str, mesh,
                     serve_mode: str = "2d",
                     variant: str | None = None) -> DryRunCase:
    """Serve cell on either the flat production mesh (baseline; the model
    axis cannot co-shard KV heads and head_dim, so GSPMD replicates pools —
    see §Perf iteration 1) or the 2-D ('kv','hd') serving view (optimized,
    default)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg, use_kernels=False, remat=False,
                        **(VARIANTS.get(variant, {}) if variant else {}))
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if serve_mode == "2d":
        model_axes = ("kv", "hd")
    else:
        model_axes = ("model",)
    # serving: TP only — FSDP would all-gather all weights per decoded token
    p_sh = param_shardings(params_shape, mesh, use_fsdp=False,
                           model_axes=model_axes)
    g, b, gaxes = _group(mesh, shape.global_batch)
    dax = (gaxes if len(gaxes) > 1 else gaxes[0]) if g > 1 else None
    kv_ax, hd_ax = (model_axes if len(model_axes) == 2
                    else (None, model_axes[0]))
    s = shape.seq_len
    dt = _dtype(cfg)
    is_decode = shape.kind == "decode"
    max_pages = -(-(s + (1 if is_decode else 0)) // PAGE_SIZE)

    tok_tail = (cfg.num_codebooks,) if (
        cfg.family == "audio" and cfg.num_codebooks > 1
    ) else ()

    def ok(dim: int, *axes) -> Any:
        """axes if the dim divides their product, else replicated"""
        prod = 1
        for a in axes:
            if a is not None:
                prod *= mesh.shape[a]
        axes = tuple(a for a in axes if a is not None)
        if not axes or dim % prod:
            return None
        return axes if len(axes) > 1 else axes[0]

    if cfg.family == "rwkv6":
        h, n = cfg.num_rwkv_heads, cfg.rwkv_head_size
        state = RecurrentState(
            tm_shift=sds((g, cfg.num_layers, b, cfg.d_model), dt),
            cm_shift=sds((g, cfg.num_layers, b, cfg.d_model), dt),
            wkv=sds((g, cfg.num_layers, b, h, n, n), jnp.float32),
            seq_lens=sds((g, b), jnp.int32),
        )
        shift_sh = _ns(mesh, dax, None, None, ok(cfg.d_model, kv_ax, hd_ax))
        st_sh = RecurrentState(
            tm_shift=shift_sh, cm_shift=shift_sh,
            wkv=_ns(mesh, dax, None, None, ok(h, kv_ax, hd_ax), None, None),
            seq_lens=_ns(mesh, dax, None),
        )
        if is_decode:
            fn = jax.vmap(model.decode_step, in_axes=(None, 0, 0))
            args = (params_shape, sds((g, b), jnp.int32), state)
            in_sh = (p_sh, _ns(mesh, dax, None), st_sh)
        else:
            fn = jax.vmap(model.prefill, in_axes=(None, 0, 0, 0))
            args = (params_shape, sds((g, b, s), jnp.int32),
                    sds((g, b), jnp.int32), state)
            in_sh = (p_sh, _ns(mesh, dax, None, None), _ns(mesh, dax, None),
                     st_sh)
    elif cfg.family == "hybrid_rglru":
        # window-bounded KV: only ceil(window/page)+2 frames live per seq
        # during decode; prefill writes the full prompt (engine frees after)
        win_pages = -(-cfg.local_window // PAGE_SIZE) + 2
        frames = (b * (max_pages if shape.kind == "prefill" else win_pages)
                  + 1)
        r = cfg.rglru_dim or cfg.d_model
        from repro.models.rglru import CONV_WIDTH
        pool = sds((g, model.n_att, frames, PAGE_SIZE, cfg.num_kv_heads,
                    cfg.head_dim), dt)
        pool_sh = _ns(mesh, dax, None, None, None,
                      ok(cfg.num_kv_heads, kv_ax), ok(cfg.head_dim, hd_ax))
        state = HybridState(
            rg_h=sds((g, model.n_rec, b, r), jnp.float32),
            conv_buf=sds((g, model.n_rec, b, CONV_WIDTH - 1, r), dt),
            k_pools=pool, v_pools=pool,
            page_table=sds((g, b, max_pages), jnp.int32),
            seq_lens=sds((g, b), jnp.int32),
        )
        st_sh = HybridState(
            rg_h=_ns(mesh, dax, None, None, ok(r, kv_ax, hd_ax)),
            conv_buf=_ns(mesh, dax, None, None, None, ok(r, kv_ax, hd_ax)),
            k_pools=pool_sh, v_pools=pool_sh,
            page_table=_ns(mesh, dax, None, None),
            seq_lens=_ns(mesh, dax, None),
        )
        if is_decode:
            fn = jax.vmap(model.decode_step, in_axes=(None, 0, 0))
            args = (params_shape, sds((g, b), jnp.int32), state)
            in_sh = (p_sh, _ns(mesh, dax, None), st_sh)
        else:
            fn = jax.vmap(model.prefill, in_axes=(None, 0, 0, 0))
            args = (params_shape, sds((g, b, s), jnp.int32),
                    sds((g, b), jnp.int32), state)
            in_sh = (p_sh, _ns(mesh, dax, None, None), _ns(mesh, dax, None),
                     st_sh)
    else:
        frames = b * max_pages + 1
        state = _paged_state_specs(
            cfg, g, b, s, frames, max_pages,
            kv_dtype=jnp.int8 if getattr(model, "kv_dtype", "native")
            == "int8" else None,
        )
        pool_sh = _ns(mesh, dax, None, None, None,
                      ok(cfg.num_kv_heads, kv_ax), ok(cfg.head_dim, hd_ax))
        st_sh = PagedKVState(
            k_pools=pool_sh, v_pools=pool_sh,
            page_table=_ns(mesh, dax, None, None),
            seq_lens=_ns(mesh, dax, None),
        )
        tok_sh = _ns(mesh, dax, *([None] * (1 + len(tok_tail))))
        if is_decode:
            fn = jax.vmap(model.decode_step, in_axes=(None, 0, 0))
            args = (params_shape, sds((g, b) + tok_tail, jnp.int32), state)
            in_sh = (p_sh, tok_sh, st_sh)
        elif cfg.family == "vlm":
            fn = jax.vmap(model.prefill, in_axes=(None, 0, 0, 0, 0))
            args = (params_shape, sds((g, b, s), jnp.int32),
                    sds((g, b), jnp.int32), state,
                    sds((g, b, N_VIS, cfg.d_model), dt))
            in_sh = (p_sh, _ns(mesh, dax, None, None), _ns(mesh, dax, None),
                     st_sh, _ns(mesh, dax, None, None, None))
        else:
            fn = jax.vmap(model.prefill, in_axes=(None, 0, 0, 0))
            args = (params_shape, sds((g, b, s) + tok_tail, jnp.int32),
                    sds((g, b), jnp.int32), state)
            in_sh = (p_sh,
                     _ns(mesh, dax, *([None] * (1 + len(tok_tail)))),
                     _ns(mesh, dax, None), st_sh)

    state_idx = 2 if is_decode else 3
    fn = jax.jit(fn, in_shardings=in_sh, donate_argnums=(state_idx,))
    tokens_per_step = (shape.global_batch if is_decode
                       else shape.global_batch * s)
    return DryRunCase(
        arch=arch, shape=shape_name, kind=shape.kind,
        fn=fn, args=args,
        model_flops_per_step=2.0 * cfg.active_param_count() * tokens_per_step,
    )


def build_case(arch: str, shape_name: str, mesh,
               serve_mode: str = "2d",
               variant: str | None = None) -> DryRunCase:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_case(arch, shape_name, mesh, variant)
    return build_serve_case(arch, shape_name, mesh, serve_mode, variant)
