"""Serving split before/after + fused decode-horizon sweep.

Section 1 (seed vs split) runs the same preempting workload through the
frozen seed engine (``repro.serve.reference.ReferenceEngine``, monolithic
host loop: full page-table re-upload each step, full-pool stack+reshape
per spill/restore) and the refactored Scheduler/Executor engine
(persistent delta-updated device page table, donated jitted steps,
page-granular spill, fused multi-step decode), and reports:

  * decode tokens/s (wall; CPU-interpret numbers — the *ratio* is the
    signal, absolute rates are hardware-dependent; the executor's timers
    ``block_until_ready`` the step outputs, so they measure execution,
    not async dispatch);
  * spill/restore bytes actually moved per context switch.  The seed's
    *counter* already counted victim pages only, so its data-plane
    pathology is reported separately as ``touched`` bytes: every seed
    spill stacks both full pools (2 x pool bytes) and every restore
    rebuilds them (2 x more), regardless of victim size;
  * page-table rows uploaded to the device per decode step (seed: all
    ``max_batch`` rows, every step).

Section 2 (horizon sweep) runs the split engine with the fused decode
horizon forced to K=1 vs auto, reporting decode tokens/s, host syncs per
decoded token (forced device->host transfers — the scalar-plane
interventions the horizon amortizes) and page-table delta syncs per
token.  ``benchmarks/run.py --only serve`` gates on the auto-horizon
numbers: greedy outputs must stay token-identical to the seed engine and
``host_syncs / decode_tokens`` must be strictly below 1.0.
"""

from __future__ import annotations

import copy
import dataclasses
import time

import numpy as np


def _workload(cfg, n=6, seed=0, max_new=12):
    from repro.serve import ServeRequest

    rng = np.random.default_rng(seed)
    return [
        ServeRequest(req_id=i,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         size=int(rng.integers(6, 16))
                                         ).astype(np.int32),
                     max_new_tokens=max_new)
        for i in range(n)
    ]


def _drive(eng, reqs):
    from repro.serve import ReferenceEngine
    from repro.serve.api import to_internal

    for r in reqs:
        r = copy.deepcopy(r)
        # the frozen seed engine predates the typed client surface: lower
        # explicitly; the split engine takes the ServeRequest itself
        eng.submit(to_internal(r) if isinstance(eng, ReferenceEngine)
                   else r)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    return done, wall


def _warm(eng_cls, model, params, cfg, serve_cfg):
    """Compile every graph the timed run can hit before timing it.

    ``max_new=12`` walks the auto-horizon ladder through K=8, 2, 1 and
    ``max_new=6`` through K=4, 1, so all power-of-two fused-decode
    variants (plus the prefill shapes) are in the jit cache — otherwise
    their compile time would land inside the timed decode region."""
    for max_new in (12, 6):
        _drive(eng_cls(model, params, serve_cfg),
               _workload(cfg, n=2, seed=1, max_new=max_new))


def run() -> tuple[list[str], dict]:
    import jax  # noqa: F401  (device init before timing)

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import Engine, ReferenceEngine, ServeConfig

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(page_size=4, num_pages=16, max_pages_per_seq=16,
                            max_batch=3)
    reqs = _workload(cfg)

    results = {}
    outs = {}
    for name, eng_cls in (("seed", ReferenceEngine), ("split", Engine)):
        # warm the jit caches so the timed run measures steady-state decode
        _warm(eng_cls, model, params, cfg, serve_cfg)
        eng = eng_cls(model, params, serve_cfg)
        done, wall = _drive(eng, reqs)
        outs[name] = {i: [int(x) for x in done[i].output] for i in done}
        c = eng.counters
        steps = c.get("decode_tokens")
        st = eng.switcher.stats
        kp = eng.kv.k_pools
        n_layers, n_frames, page, hkv, hd = kp.shape
        per_page = n_layers * page * hkv * hd * kp.dtype.itemsize
        pool_bytes = n_frames * per_page
        if name == "seed":
            # data plane actually touched: jnp.stack of BOTH full pools on
            # every spill and every restore, plus the full-pool rebuild
            # after the restore scatter (2x pool each time)
            touched = (st.switches + c.get("restores")) * 2 * pool_bytes
            # full [max_batch, max_pages] table re-uploaded on every engine
            # step that decoded (upper-bounded by total steps)
            ptab_rows = eng._step_i * eng.cfg.max_batch
        else:
            touched = st.bytes_spilled + st.bytes_restored
            ptab_rows = c.get("ptab_rows_uploaded")
        decode_s = c.seconds("decode") or wall
        results[name] = dict(
            wall=wall, tokens=sum(len(r.output) for r in done.values()),
            decode_steps=steps, decode_seconds=decode_s,
            switches=st.switches, moved=st.bytes_spilled + st.bytes_restored,
            touched=touched, ptab_rows=ptab_rows,
        )
        print(f"{name:>6}: {results[name]['tokens']} tokens in {wall:.1f}s, "
              f"{st.switches} switches, "
              f"{results[name]['moved']} B victim pages moved, "
              f"{touched} B pool bytes touched, "
              f"{ptab_rows} page-table rows uploaded")

    seed, split = results["seed"], results["split"]
    token_identical = outs["seed"] == outs["split"]
    rate_seed = seed["decode_steps"] / max(seed["decode_seconds"], 1e-9)
    rate_split = split["decode_steps"] / max(split["decode_seconds"], 1e-9)
    print(f"decode tokens/s: seed {rate_seed:.1f} -> split {rate_split:.1f} "
          f"({rate_split / max(rate_seed, 1e-9):.2f}x, CPU interpret)")
    print(f"bytes touched per switch: seed "
          f"{seed['touched'] // max(seed['switches'], 1)} -> split "
          f"{split['touched'] // max(split['switches'], 1)}")
    print(f"greedy outputs token-identical to seed at auto-horizon: "
          f"{token_identical}")

    # ---- horizon sweep: forced K=1 vs auto ---------------------------
    # a single admission wave in a roomy pool: the queue drains on step 1,
    # so the run isolates the steady-state decode loop the horizon fuses
    # (the contended seed-vs-split workload above keeps the horizon mostly
    # collapsed — by design; that is its identity stress)
    sweep_reqs = _workload(cfg, n=3, seed=2)
    sweep = {}
    for label, mh in (("h1", 1), ("auto", serve_cfg.max_horizon)):
        swp_cfg = dataclasses.replace(serve_cfg, num_pages=64,
                                      max_pages_per_seq=32, max_horizon=mh)
        _warm(Engine, model, params, cfg, swp_cfg)
        eng = Engine(model, params, swp_cfg)
        _drive(eng, sweep_reqs)
        c = eng.counters
        toks = c.get("decode_tokens")
        sweep[label] = dict(
            decode_tokens=toks,
            decode_tok_per_s=toks / max(c.seconds("decode"), 1e-9),
            host_syncs=c.get("host_syncs"),
            host_syncs_per_tok=c.ratio("host_syncs", "decode_tokens"),
            ptab_syncs=c.get("ptab_syncs"),
            ptab_syncs_per_tok=c.ratio("ptab_syncs", "decode_tokens"),
            dispatches=c.get("decode_dispatches"),
            mean_horizon=(c.get("decode_horizon")
                          / max(c.get("decode_dispatches"), 1)),
        )
        s = sweep[label]
        print(f"horizon {label:>4}: {s['decode_tok_per_s']:.1f} decode tok/s, "
              f"{s['host_syncs_per_tok']:.3f} host syncs/tok, "
              f"{s['ptab_syncs_per_tok']:.3f} ptab syncs/tok, "
              f"mean horizon {s['mean_horizon']:.2f} "
              f"({s['dispatches']} dispatches)")

    metrics = {
        "token_identical": bool(token_identical),
        "host_syncs_per_token": float(sweep["auto"]["host_syncs_per_tok"]),
        "mean_horizon": float(sweep["auto"]["mean_horizon"]),
        "decode_tok_per_s_seed": float(rate_seed),
        "decode_tok_per_s_split": float(rate_split),
        "ctx_bytes_touched_seed": int(seed["touched"]),
        "ctx_bytes_touched_split": int(split["touched"]),
        "sweep": sweep,
    }
    csv = [
        f"serve_decode_tok_per_s_seed,0,{rate_seed:.2f}",
        f"serve_decode_tok_per_s_split,0,{rate_split:.2f}",
        f"serve_ctx_bytes_touched_seed,0,{seed['touched']}",
        f"serve_ctx_bytes_touched_split,0,{split['touched']}",
        f"serve_ptab_rows_uploaded_seed,0,{seed['ptab_rows']}",
        f"serve_ptab_rows_uploaded_split,0,{split['ptab_rows']}",
        f"serve_decode_tok_per_s_h1,0,{sweep['h1']['decode_tok_per_s']:.2f}",
        f"serve_decode_tok_per_s_auto,0,"
        f"{sweep['auto']['decode_tok_per_s']:.2f}",
        f"serve_host_syncs_per_tok_h1,0,"
        f"{sweep['h1']['host_syncs_per_tok']:.4f}",
        f"serve_host_syncs_per_tok_auto,0,"
        f"{sweep['auto']['host_syncs_per_tok']:.4f}",
        f"serve_ptab_syncs_per_tok_auto,0,"
        f"{sweep['auto']['ptab_syncs_per_tok']:.4f}",
        f"serve_mean_horizon_auto,0,{sweep['auto']['mean_horizon']:.2f}",
    ]
    return csv, metrics


def main() -> list[str]:
    csv, _ = run()
    return csv


if __name__ == "__main__":
    main()
