"""Serving-engine tests: continuous batching, faults, preemption, OS costs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CostModel
from repro.models import build_model
from repro.serve import Engine, ServeConfig, ServeRequest

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    return cfg, model, model.init(KEY)


def make_requests(cfg, n, rng, max_new=10):
    return [
        ServeRequest(
            req_id=i,
            prompt=rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(4, 12))
            ).astype(np.int32),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


class TestEngine:
    def test_all_requests_complete(self, model_and_params):
        cfg, model, params = model_and_params
        eng = Engine(model, params, ServeConfig(
            page_size=4, num_pages=128, max_pages_per_seq=16, max_batch=4))
        rng = np.random.default_rng(0)
        for r in make_requests(cfg, 7, rng):
            eng.submit(r)
        done = eng.run()
        assert len(done) == 7
        assert all(len(r.output) == 10 for r in done.values())
        eng.vmem.check_invariants()

    def test_preemption_transparency(self, model_and_params):
        """Greedy outputs are bit-identical with and without preemption —
        the paper's C5/C6 correctness contract end to end."""
        cfg, model, params = model_and_params
        rng = np.random.default_rng(1)
        reqs = make_requests(cfg, 6, rng, max_new=12)

        tiny = Engine(model, params, ServeConfig(
            page_size=4, num_pages=16, max_pages_per_seq=16, max_batch=3))
        big = Engine(model, params, ServeConfig(
            page_size=4, num_pages=512, max_pages_per_seq=16, max_batch=6))
        import copy
        for r in reqs:
            tiny.submit(copy.deepcopy(r))
        for r in reqs:
            big.submit(copy.deepcopy(r))
        done_t = tiny.run()
        done_b = big.run()
        assert tiny.stats()["counters"].get("preemptions", 0) > 0
        assert big.stats()["counters"].get("preemptions", 0) == 0
        for i in range(6):
            a = [int(x) for x in done_t[i].output]
            b = [int(x) for x in done_b[i].output]
            assert a == b, f"req {i} diverged under preemption"

    def test_page_faults_counted(self, model_and_params):
        cfg, model, params = model_and_params
        eng = Engine(model, params, ServeConfig(
            page_size=4, num_pages=128, max_pages_per_seq=16, max_batch=2))
        rng = np.random.default_rng(2)
        for r in make_requests(cfg, 2, rng, max_new=9):
            eng.submit(r)
        eng.run()
        s = eng.stats()
        # 9 decode steps crossing 4-token pages -> at least 2 faults/request
        assert s["counters"]["page_faults"] >= 4
        assert s["counters"]["modeled_fault_cycles"] > 0

    def test_context_switch_cost_accounting(self, model_and_params):
        cfg, model, params = model_and_params
        eng = Engine(model, params, ServeConfig(
            page_size=4, num_pages=16, max_pages_per_seq=16, max_batch=3))
        rng = np.random.default_rng(3)
        for r in make_requests(cfg, 5, rng, max_new=12):
            eng.submit(r)
        eng.run()
        st = eng.switcher.stats
        if st.switches:
            assert st.bytes_spilled == st.bytes_restored
            # modeled cycles: >= scalar switch + data movement per switch
            cost = CostModel()
            assert st.modeled_cycles >= st.switches * (
                cost.scalar_ctx_switch_cycles
            )

    def test_queue_longer_than_slots(self, model_and_params):
        """Admission control: more requests than device slots."""
        cfg, model, params = model_and_params
        eng = Engine(model, params, ServeConfig(
            page_size=4, num_pages=256, max_pages_per_seq=16, max_batch=2))
        rng = np.random.default_rng(4)
        for r in make_requests(cfg, 9, rng, max_new=6):
            eng.submit(r)
        done = eng.run()
        assert len(done) == 9
        assert eng.vmem.num_seqs == 0  # everything unmapped at the end

    def test_scheduler_tick_accounting(self, model_and_params):
        cfg, model, params = model_and_params
        eng = Engine(model, params, ServeConfig(
            page_size=4, num_pages=256, max_pages_per_seq=16, max_batch=4,
            tick_every_steps=2))
        rng = np.random.default_rng(5)
        for r in make_requests(cfg, 4, rng, max_new=8):
            eng.submit(r)
        eng.run()
        s = eng.stats()
        assert s["counters"]["ticks"] >= 3
        assert s["counters"]["modeled_tick_cycles"] == (
            s["counters"]["ticks"] * CostModel().sched_tick_cycles
        )


def test_heavy_preemption_cascade(model_and_params):
    """Regression: a victim spilled while servicing another request's fault
    must not corrupt the decode step (engine once KeyError'd here); even
    total-preemption steps terminate and produce exact outputs."""
    import copy
    cfg, model, params = model_and_params
    rng = np.random.default_rng(9)
    reqs = [
        ServeRequest(req_id=i,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         size=int(rng.integers(5, 14))
                                         ).astype(np.int32),
                     max_new_tokens=16)
        for i in range(8)
    ]
    tiny = Engine(model, params, ServeConfig(
        page_size=4, num_pages=16, max_pages_per_seq=16, max_batch=3))
    big = Engine(model, params, ServeConfig(
        page_size=4, num_pages=1024, max_pages_per_seq=16, max_batch=8))
    for r in reqs:
        tiny.submit(copy.deepcopy(r))
    for r in reqs:
        big.submit(copy.deepcopy(r))
    done_t, done_b = tiny.run(), big.run()
    assert len(done_t) == 8
    assert tiny.stats()["counters"].get("preemptions", 0) >= 3
    for i in range(8):
        assert [int(x) for x in done_t[i].output] == \
            [int(x) for x in done_b[i].output], i
    tiny.vmem.check_invariants()


def test_prefix_sharing_exact(model_and_params):
    """System-prompt caching: requests forked from a resident prefix share
    its whole pages by refcount (copy-only-the-tail-page) and produce
    outputs bit-identical to full-prompt prefill.  Also regression-covers
    the idle-row clobber bug (a mapped-but-idle sequence's page 0 must not
    receive inactive-lane writes)."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, size=22).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
             for _ in range(3)]

    shared = Engine(model, params, ServeConfig(
        page_size=4, num_pages=64, max_pages_per_seq=32, max_batch=4))
    shared.preload_prefix(prefix)
    for i, t in enumerate(tails):
        shared.submit(ServeRequest(req_id=i, prompt=t, max_new_tokens=8,
                                   share_prefix=True))
    done_s = shared.run()
    # whole prefix pages are multi-referenced while children run; invariants
    shared.vmem.check_invariants()
    assert shared.counters.get("forked_admissions") == 3

    full = Engine(model, params, ServeConfig(
        page_size=4, num_pages=256, max_pages_per_seq=32, max_batch=4))
    for i, t in enumerate(tails):
        full.submit(ServeRequest(req_id=i,
                                 prompt=np.concatenate([prefix, t]),
                                 max_new_tokens=8))
    done_f = full.run()
    for i in range(3):
        assert [int(x) for x in done_s[i].output] == \
            [int(x) for x in done_f[i].output], i
