"""Shared kernel infrastructure.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling, MXU-aligned
block shapes) and are *validated* on CPU with ``interpret=True``, which
executes the kernel body in Python per grid step.  ``should_interpret()``
selects interpret mode automatically off-TPU so the same call sites work in
tests, benchmarks, and on real hardware.
"""

from __future__ import annotations

import functools

import jax

#: MXU systolic array dimension — matmul block shapes must be multiples.
MXU_DIM = 128
#: VPU lane count — trailing block dims should be multiples.
LANE_DIM = 128
#: Sublane count for f32 tiles.
SUBLANE_DIM = 8


@functools.cache
def should_interpret() -> bool:
    """True when not running on a real TPU (CPU validation mode)."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
