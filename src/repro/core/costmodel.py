"""AraOS cycle-cost model.

The paper evaluates *latency/overhead*, not accuracy.  This module holds the
hardware constants of the evaluated system (Cheshire + CVA6 + 2-lane Ara2 on a
VCU128 at 50 MHz) and the analytical overhead-decomposition model used by the
benchmarks.  It is deliberately separated from the functional paged-memory
code: the functional path is pure JAX and runs anywhere; these constants only
feed benchmark *reports*.

Paper constants (AraOS §3, §3.1):
  * system frequency 50 MHz on FPGA (950 MHz in 22 nm ASIC — not used here);
  * memory bandwidth 64 bit/cycle;
  * scalar context switch  ~1 k cycles;
  * vector context switch  ~3.2 k cycles (= scalar + ~2 k cycles to move the
    8-KiB VRF at 8 B/cycle, save + restore);
  * scheduler tick (100 Hz) costs ~20 k cycles to get back to the process;
  * TLB/cache pollution from the scheduler < 0.5 % of runtime;
  * DTLB: 2..128 PTEs, pseudo-LRU replacement, 4-KiB pages.

Constants the paper does *not* publish (page-table-walk latency, MMU hit
latency, mux arbitration cost) are explicit, documented parameters with
defaults chosen to land in the paper's reported overhead envelope (< 3.5 %
with >= 16 PTEs on matmul); the TLB-sweep benchmark reports sensitivity to
them.
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Published constants
# ---------------------------------------------------------------------------

FPGA_FREQ_HZ: int = 50_000_000          # Cheshire + AraOS on VCU128
MEM_BW_BITS_PER_CYCLE: int = 64         # Cheshire 64-bit AXI data path
MEM_BW_BYTES_PER_CYCLE: int = MEM_BW_BITS_PER_CYCLE // 8

PAGE_BYTES: int = 4096                  # Sv39 4-KiB pages == AXI burst bound

VRF_BYTES: int = 8 * 1024               # 2-lane Ara2, VLEN=2048: 32 regs * 256 B
SCALAR_CTX_SWITCH_CYCLES: int = 1_000   # paper: "~1k cycles"
VECTOR_STATE_MOVE_CYCLES: int = 2 * VRF_BYTES // MEM_BW_BYTES_PER_CYCLE  # ~2k
VECTOR_CTX_SWITCH_CYCLES: int = 3_200   # paper: "~3.2k cycles" measured
SCHED_TICK_HZ: int = 100
SCHED_TICK_CYCLES: int = 20_000         # paper: "~20k cycles" back-to-process
SCHED_POLLUTION_FRAC_MAX: float = 0.005  # paper: "< 0.5% of the runtime"

POST_FAULT_FLUSH_CYCLES: int = 10       # paper: backend flush FSM "~10 cycles"

# ---------------------------------------------------------------------------
# Documented assumptions (not published in the paper)
# ---------------------------------------------------------------------------

#: Cycles for a page-table walk on a DTLB miss.  Sv39 needs up to 3 dependent
#: memory accesses; with a warm page-table-walker cache most walks hit the L1
#: (write-through, 1-cycle-ish) but cold walks go to the LLC.  40 cycles is a
#: mid-estimate; the sweep benchmark reports 20/40/80 sensitivity.
DEFAULT_PTW_CYCLES: int = 40

#: Cycles for a translation request that *hits* the DTLB (req/valid handshake
#: through the shared-MMU mux, Fig. 1).
DEFAULT_MMU_HIT_CYCLES: int = 2

#: Extra arbitration cycles when the scalar core and ADDRGEN contend for the
#: time-shared MMU in the same window.
DEFAULT_MUX_CONTENTION_CYCLES: int = 1


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Cycle-cost parameters for the AraOS overhead model."""

    freq_hz: int = FPGA_FREQ_HZ
    mem_bytes_per_cycle: int = MEM_BW_BYTES_PER_CYCLE
    page_bytes: int = PAGE_BYTES
    ptw_cycles: int = DEFAULT_PTW_CYCLES
    mmu_hit_cycles: int = DEFAULT_MMU_HIT_CYCLES
    mux_contention_cycles: int = DEFAULT_MUX_CONTENTION_CYCLES
    scalar_ctx_switch_cycles: int = SCALAR_CTX_SWITCH_CYCLES
    vector_ctx_switch_cycles: int = VECTOR_CTX_SWITCH_CYCLES
    sched_tick_cycles: int = SCHED_TICK_CYCLES
    sched_tick_hz: int = SCHED_TICK_HZ
    post_fault_flush_cycles: int = POST_FAULT_FLUSH_CYCLES

    # ---- derived helpers ---------------------------------------------------

    def seconds(self, cycles: float) -> float:
        return cycles / self.freq_hz

    def bytes_move_cycles(self, nbytes: int) -> int:
        """Cycles to stream `nbytes` through the 64-bit memory path."""
        return -(-nbytes // self.mem_bytes_per_cycle)  # ceil div

    def context_switch_cycles(self, vector_state_bytes: int) -> int:
        """Scalar switch + save & restore of `vector_state_bytes` of state.

        With the paper's VRF (8 KiB) this reproduces the measured ~3.2 k
        cycles: 1 k scalar + 2 * 1 k move.
        """
        move = 2 * self.bytes_move_cycles(vector_state_bytes)
        return self.scalar_ctx_switch_cycles + move

    def tick_overhead_fraction(self, runtime_cycles: float) -> float:
        """Fraction of runtime lost to 100-Hz scheduler ticks (no switch)."""
        runtime_s = self.seconds(runtime_cycles)
        n_ticks = runtime_s * self.sched_tick_hz
        return (n_ticks * self.sched_tick_cycles) / max(runtime_cycles, 1.0)


# ---------------------------------------------------------------------------
# TPU v5e roofline constants (target hardware of the JAX port)
# ---------------------------------------------------------------------------

TPU_PEAK_FLOPS_BF16: float = 197e12     # per chip
TPU_HBM_BW: float = 819e9               # bytes/s per chip
TPU_ICI_BW_PER_LINK: float = 50e9       # bytes/s per link
TPU_VMEM_BYTES: int = 128 * 1024 * 1024  # ~128 MiB VMEM per chip (v5e ~128MB)
TPU_HBM_BYTES: int = 16 * 1024**3       # 16 GiB HBM per v5e chip
