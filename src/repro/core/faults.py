"""Page-fault and resume semantics (the vstart protocol).

AraOS handles page faults *precisely* in the middle of vector memory
instructions: the ADDRGEN stops issuing translations, the index of the faulty
element is saved into the ``vstart`` CSR, the frontend stalls until older
operations commit, and a flush FSM clears the backend (~10 cycles).  Resuming
the instruction with the recorded ``vstart`` must produce the same
architectural state as an uninterrupted run.

On TPU a compiled kernel cannot fault mid-flight, so the *mechanism* does not
transfer (DESIGN.md §2) — but the *semantics* do:

  * faults are raised by the host-side translation layer (``VirtualMemory``)
    before a kernel is dispatched with an unmapped page;
  * :class:`PageFault` carries the vstart-equivalent element index;
  * :class:`ResumeCursor` re-expresses "restart this operation at element
    vstart" for host-driven loops (prefill chunks, decode steps);
  * the property test ``faulted + resumed == uninterrupted`` is the C5
    correctness claim.
"""

from __future__ import annotations

import dataclasses


class OutOfPagesError(RuntimeError):
    """The physical pool (or slot table) cannot satisfy an allocation.

    The scheduler responds with a context switch: preempt a victim sequence,
    spill its state, retry.  Mirrors the OS reclaiming frames.
    """

    def __init__(self, requested: int, available: int, kind: str = "pages"):
        self.requested = requested
        self.available = available
        self.kind = kind
        super().__init__(
            f"out of {kind}: requested {requested}, available {available}"
        )


@dataclasses.dataclass(frozen=True)
class PageFault(Exception):
    """A precise page fault.

    ``vstart`` is the index of the first element of the current operation
    that could not be translated — the direct analogue of RVV's vstart CSR.
    Elements ``[0, vstart)`` have committed; the operation must resume at
    ``vstart`` after the fault is serviced.
    """

    seq_id: int
    logical_page: int
    vstart: int

    def __str__(self) -> str:  # Exception with dataclass needs explicit str
        return (
            f"PageFault(seq={self.seq_id}, lpn={self.logical_page}, "
            f"vstart={self.vstart})"
        )


@dataclasses.dataclass
class ResumeCursor:
    """Progress cursor for a resumable vector operation.

    Host-driven loops (chunked prefill, long copies) advance the cursor as
    elements commit; on a fault they record vstart, service the fault, and
    continue from where they stopped.  ``committed`` only moves forward —
    re-execution of committed elements is forbidden (precise-exception
    contract).
    """

    total: int
    committed: int = 0
    faults_taken: int = 0

    @property
    def done(self) -> bool:
        return self.committed >= self.total

    @property
    def remaining(self) -> int:
        return self.total - self.committed

    def advance(self, n: int) -> None:
        if n < 0:
            raise ValueError("cannot advance backwards")
        if self.committed + n > self.total:
            raise ValueError("advance past end of operation")
        self.committed += n

    def record_fault(self, fault: PageFault) -> None:
        """Advance to the faulting element: [committed, vstart) committed."""
        if fault.vstart < 0:
            raise ValueError("negative vstart")
        self.advance(fault.vstart)
        self.faults_taken += 1
