"""Paged decode attention — the ADDRGEN/MMU analogue (paper C1 + C2).

One decode step: each sequence's new query attends to its KV cache, which
lives in *physical pages* of a shared HBM pool.  The per-sequence page table
and sequence lengths are **scalar-prefetched into SMEM** and consumed by the
BlockSpec index maps: the logical->physical translation of a page happens
strictly *before* the page's data burst is fetched into VMEM — the literal
TPU restatement of Ara2's ADDRGEN requesting a translation from CVA6's MMU
before issuing each page-bounded AXI burst.  One translation per
``page_size``-token burst; zero per-element translation on this unit-stride
path.

Layouts:
  q        [B, Hkv, G, D]     grouped query heads (G = Hq / Hkv)
  k_pool   [P, page, Hkv, D]  physical pages (shared pool)
  v_pool   [P, page, Hkv, D]
  page_table [B, max_pages]   int32, INVALID_PAGE (-1) for unmapped
  seq_lens [B]                int32 tokens currently valid

Grid ``(B, Hkv, max_pages)`` with an online softmax over the page sweep;
pages at or beyond a sequence's length are skipped with ``pl.when`` (no MXU
work, no data burst consumed from VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import should_interpret

_NEG_INF = -1e30


def _paged_attn_kernel(
    seq_lens_ref,      # SMEM [B]
    page_table_ref,    # SMEM [B, max_pages]  (prefetched; used by index maps)
    kv_scale_ref,      # SMEM [1] f32 — dequant scale (1.0 when not quantized)
    q_ref,             # VMEM [1, 1, G, D]
    k_ref,             # VMEM [1, page, 1, D]  (translated burst)
    v_ref,             # VMEM [1, page, 1, D]
    o_ref,             # VMEM [1, 1, G, D]
    m_ref, l_ref, acc_ref,
    *,
    page_size: int,
    scale: float,
    window: int | None,
    quantized: bool,
):
    del page_table_ref  # translation consumed by the index maps
    b, p = pl.program_id(0), pl.program_id(2)
    seq_len = seq_lens_ref[b]
    # sliding window: only positions in [lo, seq_len) are visible
    lo = jnp.maximum(seq_len - window, 0) if window is not None else 0

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Page p holds tokens [p*page, (p+1)*page); active iff it intersects
    # [lo, seq_len).  Inactive pages issue no MXU work (paper C4's flip
    # side: wasted bursts are never fetched).
    @pl.when((p * page_size < seq_len) & ((p + 1) * page_size > lo))
    def _body():
        q = q_ref[0, 0]                               # [G, D]
        k = k_ref[0, :, 0, :]                         # [page, D]
        v = v_ref[0, :, 0, :]                         # [page, D]
        if quantized:
            # The burst arrived as int8 bytes; upcast in VMEM *after* the
            # DMA so HBM traffic stays at the quantized width.  Dequantize
            # to the query's compute dtype — same precision as the fp path.
            k = (k.astype(jnp.float32) * kv_scale_ref[0]).astype(q.dtype)
            v = (v.astype(jnp.float32) * kv_scale_ref[0]).astype(q.dtype)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # [G, page]
        pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1
        )
        s = jnp.where((pos < seq_len) & (pos >= lo), s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            pexp.astype(v.dtype), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(p == pl.num_programs(2) - 1)
    def _store():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("page_size", "scale", "window", "kv_scale", "interpret"),
)
def paged_decode_attention(
    q: jax.Array,            # [B, Hkv, G, D]
    k_pool: jax.Array,       # [P, page, Hkv, D]  (model dtype or int8)
    v_pool: jax.Array,       # [P, page, Hkv, D]
    page_table: jax.Array,   # [B, max_pages] int32
    seq_lens: jax.Array,     # [B] int32
    *,
    page_size: int,
    scale: float | None = None,
    window: int | None = None,
    kv_scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One decode step through the page table. Returns [B, Hkv, G, D].

    When ``kv_scale`` is given the pools hold quantized integers; the
    scale rides in the scalar-prefetch plane next to the page table and
    each K/V tile is dequantized (``x * kv_scale``) in VMEM after its
    burst lands — HBM moves the narrow bytes, the MXU sees ``q.dtype``.
    """
    if interpret is None:
        interpret = should_interpret()
    b, hkv, g, d = q.shape
    n_pages, page, _, _ = k_pool.shape
    assert page == page_size, (page, page_size)
    max_pages = page_table.shape[1]
    scale = scale if scale is not None else d ** -0.5

    def kv_index(bi, h, p, seq_lens_ref, page_table_ref, *_):
        del seq_lens_ref
        # THE translation: logical page p of sequence bi -> physical frame.
        # Unmapped entries (-1) clamp to frame 0; the kernel's seq_len guard
        # ensures their data is never used.
        frame = jnp.maximum(page_table_ref[bi, p], 0)
        return (frame, 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, h, p, *_: (bi, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, d), kv_index),
            pl.BlockSpec((1, page_size, 1, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, h, p, *_: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_attn_kernel, page_size=page_size, scale=scale,
            window=window, quantized=kv_scale is not None,
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(seq_lens.astype(jnp.int32), page_table.astype(jnp.int32),
      jnp.full((1,), 1.0 if kv_scale is None else kv_scale, jnp.float32),
      q, k_pool, v_pool)
