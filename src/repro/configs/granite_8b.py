"""Granite-8B-Code — llama-arch dense GQA [arXiv:2405.04324; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense", num_layers=36, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=49152,
    head_dim=128, rope_theta=10_000_000.0,
)

REDUCED = ModelConfig(
    name="granite-8b-reduced", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
    head_dim=16, param_dtype="float32",
)
