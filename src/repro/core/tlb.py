"""Software TLB model with tree-PLRU replacement + trace-driven simulator.

CVA6's DTLB is fully associative with pseudo-LRU replacement; the paper sweeps
it from 2 to 128 entries and attributes the residual overhead at 128 entries
(< 1 %) to PLRU's non-optimality.  :class:`TLB` reproduces that structure
exactly (tree-PLRU over a fully-associative array), and
:class:`SharedMMUSimulator` replays *interleaved* scalar/vector address traces
through one shared TLB — the time-multiplexed MMU of Fig. 1 — producing the
three-way overhead decomposition of Fig. 2(b,c,d):

  1. CVA6 overhead   — visible stalls on scalar-issued translations;
  2. Ara2 overhead   — visible stalls on vector-issued translations;
  3. mux + pollution — arbitration cycles when both requesters contend, plus
     scheduler-induced TLB pollution.

The latency-hiding effect (paper C4: "Ara2's FPU computation can overlap and
hide the stalls from DTLB misses") is modeled per event: each translation
carries ``slack`` cycles of concurrent compute that can absorb the miss
penalty; the *visible* stall is ``max(0, penalty - slack)``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Sequence

import numpy as np

from repro.core.costmodel import CostModel

SCALAR = 0  # CVA6-issued translation
VECTOR = 1  # Ara2/ADDRGEN-issued translation


class TLB:
    """Fully-associative TLB with tree-PLRU replacement.

    ``entries`` must be a power of two (CVA6 configs: 2..128).  The PLRU tree
    has ``entries - 1`` internal nodes stored as a flat heap; on an access the
    bits along the leaf's path are pointed *away* from it, and the victim is
    found by following the bits from the root.
    """

    def __init__(self, entries: int):
        if entries < 1 or (entries & (entries - 1)) != 0:
            raise ValueError(f"TLB entries must be a power of two, got {entries}")
        self.entries = entries
        self._tags = np.full(entries, -1, dtype=np.int64)
        self._plru = np.zeros(max(entries - 1, 1), dtype=np.int8)
        self.hits = 0
        self.misses = 0

    # ---- PLRU tree helpers ----------------------------------------------

    def _touch(self, way: int) -> None:
        """Point every node on the path away from `way` (MRU update)."""
        if self.entries == 1:
            return
        node = 0
        lo, hi = 0, self.entries
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if way < mid:  # leaf in left subtree -> point right (away)
                self._plru[node] = 1
                node = 2 * node + 1
                hi = mid
            else:          # leaf in right subtree -> point left (away)
                self._plru[node] = 0
                node = 2 * node + 2
                lo = mid
        assert lo == way

    def _victim(self) -> int:
        """Follow the PLRU bits from the root to the victim leaf."""
        if self.entries == 1:
            return 0
        node = 0
        lo, hi = 0, self.entries
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if self._plru[node] == 0:  # points left
                node = 2 * node + 1
                hi = mid
            else:
                node = 2 * node + 2
                lo = mid
        return lo

    # ---- public API -------------------------------------------------------

    def access(self, vpn: int) -> bool:
        """Look up ``vpn``; fill on miss. Returns True on hit."""
        hit_ways = np.nonzero(self._tags == vpn)[0]
        if hit_ways.size:
            self.hits += 1
            self._touch(int(hit_ways[0]))
            return True
        self.misses += 1
        # Hardware fills invalid ways before consulting PLRU for a victim.
        invalid = np.nonzero(self._tags == -1)[0]
        way = int(invalid[0]) if invalid.size else self._victim()
        self._tags[way] = vpn
        self._touch(way)
        return False

    def flush(self) -> None:
        """sfence.vma equivalent — also models scheduler TLB pollution."""
        self._tags[:] = -1
        self._plru[:] = 0

    def pollute(self, n: int, rng: np.random.Generator) -> None:
        """Evict via ``n`` accesses to fresh VPNs (scheduler interference)."""
        base = -2 - int(rng.integers(0, 2**31))
        h, m = self.hits, self.misses
        for i in range(n):
            self.access(base - i)
        self.hits, self.misses = h, m  # pollution is not workload traffic

    @property
    def resident(self) -> set[int]:
        return {int(t) for t in self._tags if t >= 0}


@dataclasses.dataclass(frozen=True)
class AccessEvent:
    """One translation request issued to the shared MMU.

    ``slack``: cycles of concurrent vector compute available to hide a miss
    on this request (0 for fully exposed scalar loads in serial sections).
    """

    source: int  # SCALAR | VECTOR
    vpn: int
    slack: float = 0.0


@dataclasses.dataclass
class OverheadReport:
    """Fig. 2-style decomposition (all in cycles, plus totals)."""

    cva6_cycles: float = 0.0
    ara2_cycles: float = 0.0
    mux_pollution_cycles: float = 0.0
    translations: int = 0
    hits: int = 0
    misses: int = 0
    scalar_misses: int = 0
    vector_misses: int = 0

    @property
    def total_cycles(self) -> float:
        return self.cva6_cycles + self.ara2_cycles + self.mux_pollution_cycles

    def overhead_fraction(self, baseline_cycles: float) -> float:
        """Overhead relative to the bare-metal (no-translation) runtime."""
        return self.total_cycles / max(baseline_cycles, 1.0)

    def decomposed_fractions(self, baseline_cycles: float) -> dict[str, float]:
        b = max(baseline_cycles, 1.0)
        return {
            "cva6": self.cva6_cycles / b,
            "ara2": self.ara2_cycles / b,
            "mux_pollution": self.mux_pollution_cycles / b,
            "total": self.total_cycles / b,
        }


class SharedMMUSimulator:
    """Replay an interleaved scalar/vector trace through one shared TLB.

    Mirrors the time-multiplexed MMU: a single TLB serves both requesters;
    adjacent requests from *different* sources pay an arbitration cost
    (``mux_contention_cycles``).  Hit latency is pipelined away for the
    vector unit (translation happens ahead of the burst) but counts for the
    scalar core only when it has no slack.
    """

    def __init__(self, tlb_entries: int, cost: CostModel | None = None,
                 seed: int = 0):
        self.tlb = TLB(tlb_entries)
        self.cost = cost or CostModel()
        self._rng = np.random.default_rng(seed)

    def run(
        self,
        events: Iterable[AccessEvent],
        *,
        pollution_evictions_per_tick: int = 0,
        num_ticks: int = 0,
    ) -> OverheadReport:
        rep = OverheadReport()
        prev_source: int | None = None
        prev_missed = False
        events = list(events)
        # Scheduler pollution: spread tick evictions evenly across the trace.
        tick_every = len(events) // num_ticks if num_ticks else 0
        for i, ev in enumerate(events):
            if tick_every and i and i % tick_every == 0:
                self.tlb.pollute(pollution_evictions_per_tick, self._rng)
                rep.mux_pollution_cycles += (
                    pollution_evictions_per_tick * self.cost.ptw_cycles * 0.5
                )
            rep.translations += 1
            hit = self.tlb.access(ev.vpn)
            penalty = self.cost.mmu_hit_cycles if hit else (
                self.cost.mmu_hit_cycles + self.cost.ptw_cycles
            )
            if hit:
                rep.hits += 1
            else:
                rep.misses += 1
                if ev.source == SCALAR:
                    rep.scalar_misses += 1
                else:
                    rep.vector_misses += 1
            visible = max(0.0, penalty - ev.slack)
            if ev.source == SCALAR:
                rep.cva6_cycles += visible
            else:
                rep.ara2_cycles += visible
            # Arbitration is only paid when the other requester arrives
            # while the shared MMU is still busy with a page-table walk
            # (hits are single-cycle and pipeline through the mux).
            if (prev_source is not None and prev_source != ev.source
                    and prev_missed):
                rep.mux_pollution_cycles += self.cost.mux_contention_cycles
            prev_source = ev.source
            prev_missed = not hit
        return rep


def interleave(
    scalar_vpns: Sequence[int],
    vector_vpns: Sequence[int],
    *,
    scalar_slack: float,
    vector_slack: float,
    ratio: int = 1,
) -> Iterator[AccessEvent]:
    """Interleave scalar and vector translation streams.

    ``ratio`` scalar events are issued per vector event (matmul interleaves
    scalar pointer/loop loads with vector row bursts — the paper picked
    matmul precisely because it "heavily requires the cooperation of the
    scalar core").
    """
    si, vi = 0, 0
    while si < len(scalar_vpns) or vi < len(vector_vpns):
        for _ in range(ratio):
            if si < len(scalar_vpns):
                yield AccessEvent(SCALAR, int(scalar_vpns[si]), scalar_slack)
                si += 1
        if vi < len(vector_vpns):
            yield AccessEvent(VECTOR, int(vector_vpns[vi]), vector_slack)
            vi += 1
