"""RecurrentGemma-9B — RG-LRU + local attention, 1:2 [arXiv:2402.19427]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid_rglru", num_layers=38,
    d_model=4096, num_heads=16, num_kv_heads=1, d_ff=12288,
    vocab_size=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "local"), local_window=2048,
    rglru_dim=4096, rope_theta=10_000.0,
)

REDUCED = ModelConfig(
    name="recurrentgemma-9b-reduced", family="hybrid_rglru", num_layers=5,
    d_model=64, num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=128,
    head_dim=16, block_pattern=("rglru", "rglru", "local"), local_window=16,
    rglru_dim=64, param_dtype="float32",
)
