"""Per-architecture smoke tests (reduced configs) + serving consistency.

Every assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU asserting output shapes and finiteness (spec
requirement).  The golden consistency tests assert the serving contract:
prefill + paged decode produce exactly the logits of the monolithic forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core import VMemConfig, VirtualMemory
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16, key=KEY):
    shape = (b, s + 1, cfg.num_codebooks) if (
        cfg.family == "audio" and cfg.num_codebooks > 1
    ) else (b, s + 1)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.family == "vlm":
        batch["positions"] = jnp.broadcast_to(jnp.arange(s), (3, b, s))
        batch["vision_embeds"] = (
            jax.random.normal(jax.random.fold_in(key, 1), (b, 4, cfg.d_model))
            * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_train_step(arch):
    """One forward + loss + grad step per assigned architecture."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    batch = make_batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_output_shapes(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    batch = make_batch(cfg)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        h, _ = model.forward(params, batch["tokens"],
                             batch.get("positions"),
                             batch.get("vision_embeds"))
        assert h.shape[:2] == batch["tokens"].shape[:2]
        logits = model.logits_fn(params, h)
    else:
        if cfg.family == "rwkv6":
            h, _ = model.forward(params, batch["tokens"])
        else:
            h = model.forward(params, batch["tokens"])
        logits = h @ params["head"]
    assert logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def _golden_paged(arch, steps=3, tol=1e-3):
    """prefill + decode through paged VM == monolithic forward.

    MoE models compare with the drop-free ragged dispatch on both sides
    (the sorted training dispatch drops tokens at capacity by design, so
    it cannot be the serving oracle)."""
    cfg = get_config(arch, reduced=True)
    kwargs = {"moe_dispatch": "ragged"} if cfg.family == "moe" else {}
    model = build_model(cfg, remat=False, **kwargs)
    params = model.init(KEY)
    B, PROMPT, PAGE = 2, 10, 4
    tok_shape = (B, PROMPT + steps + 1) + (
        (cfg.num_codebooks,) if cfg.family == "audio" and cfg.num_codebooks > 1
        else ()
    )
    tokens = jax.random.randint(jax.random.fold_in(KEY, 7), tok_shape, 0,
                                cfg.vocab_size)
    vm = VirtualMemory(VMemConfig(page_size=PAGE, num_pages=64,
                                  max_pages_per_seq=16, max_seqs=B))
    for i in range(B):
        vm.map_seq(i, PROMPT)
    if cfg.family == "hybrid_rglru":
        state = model.init_state(B, 64, PAGE, 16)
    else:
        state = model.init_kv_state(B, 64, PAGE, 16)
    state = state._replace(page_table=vm.device_page_table())
    plens = jnp.full((B,), PROMPT, jnp.int32)
    logits_p, state = model.prefill(params, tokens[:, :PROMPT], plens, state)

    def fwd_logits(upto):
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            h, _ = model.forward(params, tokens[:, :upto])
            return model.logits_fn(params, h)[:, -1]
        h = model.forward(params, tokens[:, :upto])
        return (h @ params["head"])[:, -1]

    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(fwd_logits(PROMPT), np.float32), rtol=tol, atol=tol,
    )
    for s in range(steps):
        nxt = tokens[:, PROMPT + s]
        for b in range(B):
            vm.append_tokens(b, 1)
        state = state._replace(page_table=vm.device_page_table())
        logits_d, state = model.decode_step(params, nxt, state)
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(fwd_logits(PROMPT + s + 1), np.float32),
            rtol=tol, atol=tol,
        )


@pytest.mark.parametrize("arch", [
    "qwen2-7b", "granite-moe-1b-a400m", "recurrentgemma-9b", "musicgen-large",
])
def test_golden_paged_serving(arch):
    """Serving through the paged VM is exact vs the monolithic forward."""
    _golden_paged(arch)


def test_golden_rwkv_serving():
    """RWKV: recurrent-state serving == monolithic forward."""
    cfg = get_config("rwkv6-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    B, PROMPT = 2, 10
    tokens = jax.random.randint(KEY, (B, 16), 0, cfg.vocab_size)
    state = model.init_state(B)
    logits_p, state = model.prefill(
        params, tokens[:, :PROMPT], jnp.full((B,), PROMPT, jnp.int32), state
    )
    h, _ = model.forward(params, tokens[:, :PROMPT])
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray((h @ params["head"])[:, -1]),
        rtol=1e-3, atol=1e-3,
    )
    for s in range(4):
        logits_d, state = model.decode_step(params, tokens[:, PROMPT + s], state)
        h, _ = model.forward(params, tokens[:, :PROMPT + s + 1])
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray((h @ params["head"])[:, -1]),
            rtol=2e-3, atol=2e-3,
        )


def test_loss_decreases_dense():
    """A few optimizer steps reduce the loss (end-to-end sanity)."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = get_config("granite-8b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    opt = adamw_init(params)
    opt_cfg = AdamWConfig(base_lr=3e-3, warmup_steps=2, total_steps=30)
    batch = make_batch(cfg, b=4, s=32)
    first = last = None

    @jax.jit
    def step(p, o, b):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        p2, o2, _ = adamw_update(grads, o, p, opt_cfg)
        return p2, o2, loss

    for i in range(15):
        params, opt, loss = step(params, opt, batch)
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < first * 0.9, (first, last)


def test_mrope_reduces_to_rope_for_text():
    """Text tokens (t==h==w positions) under M-RoPE == standard RoPE."""
    from repro.models.layers import apply_mrope, apply_rope

    x = jax.random.normal(KEY, (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    pos3 = jnp.broadcast_to(pos, (3, 2, 8))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)


def test_param_counts_match_published():
    """Full configs land near the published parameter counts."""
    expected = {
        "qwen2-72b": 72e9, "qwen2-7b": 7.6e9, "granite-8b": 8e9,
        "deepseek-67b": 67e9, "rwkv6-7b": 7.5e9,
        "llama4-maverick-400b-a17b": 400e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert 0.8 * want < got < 1.25 * want, (arch, got, want)
