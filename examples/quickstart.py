"""Quickstart: the paged virtual-memory subsystem in five minutes.

Demonstrates the paper's core loop end to end on CPU:
  1. map a sequence into paged memory (page tables, frame allocator);
  2. write through translation with one burst per page (C2-burst);
  3. read back with per-element translation (C2-indexed) and count the
     asymmetry the paper measures on spmv/canneal;
  4. take a page fault mid-stream, service it, resume at vstart (C5);
  5. replay the recorded address trace through the DTLB simulator across
     the paper's 2..128-entry sweep (Fig. 2 machinery).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AccessEvent,
    PageFault,
    ResumeCursor,
    SharedMMUSimulator,
    VECTOR,
    VMemConfig,
    VirtualMemory,
    burst_trace,
    element_trace,
)
from repro.kernels import ops

PAGE = 8


def main() -> None:
    vm = VirtualMemory(VMemConfig(
        page_size=PAGE, num_pages=64, max_pages_per_seq=16, max_seqs=2,
    ))

    # -- 1. map a 50-token sequence ------------------------------------
    vm.map_seq(0, 50)
    print(f"mapped seq 0: {len(vm.seq(0).pages)} physical pages "
          f"{vm.seq(0).pages}")

    # -- 2. unit-stride write: one translation per page burst -----------
    src = jnp.arange(50 * 4, dtype=jnp.float32).reshape(1, 50, 4)
    pool = jnp.zeros((64, PAGE, 4))
    pool = ops.paged_copy(
        src, pool, vm.device_page_table()[:1], jnp.array([50]),
        page_size=PAGE,
    )
    bursts = burst_trace(np.arange(50), PAGE)
    print(f"unit-stride write of 50 tokens -> {bursts.size} translations "
          f"(one per page burst)")

    # -- 3. indexed gather: one translation per ELEMENT ------------------
    idx = np.array([3, 49, 0, 17, 17, 33, 8, 9])
    row = vm.device_page_table()[0]
    gathered = ops.paged_gather(pool, row, jnp.asarray(idx), page_size=PAGE)
    elems = element_trace(idx, PAGE)
    print(f"indexed gather of {idx.size} elements -> {elems.size} "
          f"translations (the spmv/canneal penalty, paper §3.2)")
    np.testing.assert_allclose(
        np.asarray(gathered), np.asarray(src[0, idx])
    )

    # -- 4. page fault + vstart resume -----------------------------------
    cursor = ResumeCursor(total=80)
    out = np.zeros(80, np.float32)
    data = np.arange(80, dtype=np.float32)
    while not cursor.done:
        want = np.arange(cursor.committed, 80)
        try:
            phys = vm.translate(0, want)
        except PageFault as f:
            good = want[: f.vstart]
            if good.size:
                out[good] = data[good]
            cursor.record_fault(f)
            vm.append_tokens(0, PAGE)  # service: allocate one more page
            continue
        out[want] = data[want]
        cursor.advance(want.size)
    print(f"faulted copy finished after {cursor.faults_taken} page faults; "
          f"output exact: {bool((out == data).all())}")

    # -- 5. DTLB sweep over the real trace --------------------------------
    trace = element_trace(np.tile(np.arange(80), 20), PAGE)
    print("\nDTLB sweep (trace from step 4's address stream):")
    for entries in (2, 4, 8, 16, 32):
        sim = SharedMMUSimulator(entries)
        rep = sim.run([AccessEvent(VECTOR, int(v), slack=5.0) for v in trace])
        print(f"  {entries:3d} entries: {rep.misses:4d} misses, "
              f"visible stall {rep.ara2_cycles:7.0f} cycles")


if __name__ == "__main__":
    main()
