"""Quantized int8 KV pools: accuracy envelope + bytes on every hot path.

AraOS's reach argument applied to dtype: Ara's multi-precision datapath
shows narrower element types are the cheapest way to multiply effective
reach per byte moved.  Here the same serving-shaped workload (a preloaded
shared prefix, forked continuation prefills, a pool tight enough to force
a context switch) runs through four engines over ONE set of weights:

  fp         — native-dtype pools, Pallas kernels (the baseline stream);
  int8       — int8 pools, kernels dequantize in VMEM (the tentpole path);
  int8_ref   — int8 pools through the explicit jnp ref-path hatch: the
               gathered-pages oracle, the bytes baseline AND the
               differential ground truth (its tokens must equal int8's);
  int8_mesh  — int8 pools on a ('kv','hd') host serve mesh (1x1 on a
               single device) — the PR 6 shard_map dispatch with
               quantization on.

Gated invariants (``benchmarks/run.py --only quant``):

  * kernels live under quantization: ``ref_path_dispatches == 0`` with
    ``kernel_dispatches > 0`` and ``quant_dispatches > 0`` on the int8
    and int8_mesh engines (int8 used to force the ref path);
  * int8 token streams identical across kernel / ref-oracle / mesh
    engines — the in-kernel dequant matches the jnp oracle at argmax;
  * greedy top-1 agreement vs the fp engine at or above a fixed
    threshold (positionwise over a deterministic workload; divergence
    compounds after a first flip, so the bar is far below 1.0 but far
    above the ~1/vocab floor a broken dequant produces);
  * bytes-per-page and bytes_spilled shrink by EXACTLY the pool itemsize
    ratio (>= 2x, so "halved" holds as an inequality; the reduced config
    stores fp pools in float32, making the ratio 4) with the SAME pages
    spilled — scheduling is dtype-blind, only the bytes narrow;
  * continuation prefill still gathers strictly fewer bytes on the int8
    kernel path than the int8 ref baseline (the PR 2/6 streaming win
    survives quantization).

Also recorded (not gated): ``logit_max_abs_err`` from a teacher-forced
model-level probe — prefill the same tokens through fp and int8 pools,
take one decode step reading the pools back, and compare the logits —
the accuracy envelope at the precision where the divergence starts,
uncontaminated by compounding.
"""

from __future__ import annotations

import copy

import numpy as np

AGREEMENT_THRESHOLD = 0.5   # measured 0.675 on this fixed workload; a
                            # broken dequant lands near 1/vocab ~ 0.008


def _workload(cfg, n=5, seed=0, max_new=16):
    from repro.serve import ServeRequest

    r = np.random.default_rng(seed)
    return [
        ServeRequest(req_id=i,
                     prompt=r.integers(0, cfg.vocab_size,
                                       size=int(r.integers(4, 11))
                                       ).astype(np.int32),
                     max_new_tokens=max_new, share_prefix=True)
        for i in range(n)
    ]


def _drive(model, params, serve_cfg, prefix, reqs, mesh=None):
    from repro.serve import Engine

    eng = Engine(model, params, serve_cfg, mesh=mesh)
    eng.preload_prefix(prefix)
    for r in reqs:
        eng.submit(copy.deepcopy(r))
    done = eng.run()
    outs = {i: [int(x) for x in done[i].output] for i in done}
    c = eng.counters
    st = eng.switcher.stats
    kp, vp = eng.kv.k_pools, eng.kv.v_pools
    return outs, dict(
        pool_dtype=str(kp.dtype),
        bytes_per_page=(int(kp.nbytes) + int(vp.nbytes)) // kp.shape[1],
        kernel_dispatches=c.get("kernel_dispatches"),
        ref_path_dispatches=c.get("ref_path_dispatches"),
        quant_dispatches=c.get("quant_dispatches"),
        switches=st.switches,
        bytes_spilled=st.bytes_spilled,
        pages_spilled=st.pages_spilled,
        prefill_bytes_gathered=c.get("prefill_bytes_gathered"),
        statuses=sorted({done[i].status for i in done}),
    )


def _logit_probe(model_fp, model_q, params, cfg, seed=3):
    """Teacher-forced decode-logit divergence between fp and int8 pools.

    Both models prefill the SAME tokens (prefill logits never read the
    pools, so they must match bitwise — asserted), then take one decode
    step on the fp argmax token: the first compute that reads quantized
    pages back.  Returns (max |logit_fp - logit_int8|, argmax agreement
    over the probe batch)."""
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    b, plen, page, max_pages = 2, 12, 4, 8
    n_pages = b * max_pages
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(b, plen)), jnp.int32
    )
    plens = jnp.asarray([plen, plen - 3], jnp.int32)
    # row-major identity mapping: every logical page of every row gets a
    # distinct physical frame, so both models read back what they wrote
    table = jnp.arange(n_pages, dtype=jnp.int32).reshape(b, max_pages)

    def run(model):
        st = model.init_kv_state(b, n_pages, page, max_pages)
        st = st._replace(page_table=table)
        logits_p, st = model.prefill(params, prompts, plens, st)
        return logits_p, st

    lp_fp, st_fp = run(model_fp)
    lp_q, st_q = run(model_q)
    prefill_err = float(jnp.abs(lp_fp - lp_q).max())
    assert prefill_err == 0.0, (
        f"prefill logits read no pools and must match bitwise "
        f"(got max abs err {prefill_err})"
    )
    tok = jnp.argmax(lp_fp, axis=-1).astype(jnp.int32)
    ld_fp, _ = model_fp.decode_step(params, tok, st_fp)
    ld_q, _ = model_q.decode_step(params, tok, st_q)
    err = float(jnp.abs(ld_fp.astype(jnp.float32)
                        - ld_q.astype(jnp.float32)).max())
    agree = float(jnp.mean(
        (jnp.argmax(ld_fp, -1) == jnp.argmax(ld_q, -1)).astype(jnp.float32)
    ))
    return err, agree


def run() -> tuple[list[str], dict]:
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_host_serve_mesh
    from repro.models import build_model
    from repro.serve import ServeConfig

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False, use_kernels=True)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_serve_mesh(cfg.num_kv_heads, cfg.head_dim)

    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    reqs = _workload(cfg)

    def serve_cfg(kv_dtype, use_ref_path=False):
        # tight pool (bench_serve_throughput's preempting shape): 3 lanes
        # of prefix+prompt+16 new tokens over 15 usable frames forces at
        # least one spill, so bytes_spilled is exercised, not just counted
        return ServeConfig(page_size=4, num_pages=16, max_pages_per_seq=16,
                           max_batch=3, kv_dtype=kv_dtype,
                           use_ref_path=use_ref_path)

    outs, stats = {}, {}
    runs = [
        ("fp", serve_cfg("native"), None),
        ("int8", serve_cfg("int8"), None),
        ("int8_ref", serve_cfg("int8", use_ref_path=True), None),
        ("int8_mesh", serve_cfg("int8"), mesh),
    ]
    for name, sc, m in runs:
        outs[name], stats[name] = _drive(model, params, sc, prefix, reqs,
                                         mesh=m)
        s = stats[name]
        print(f"{name:>9}: pools {s['pool_dtype']:>7} "
              f"({s['bytes_per_page']} B/page), "
              f"{s['kernel_dispatches']} kernel / "
              f"{s['ref_path_dispatches']} ref / "
              f"{s['quant_dispatches']} quant dispatches, "
              f"{s['switches']} switches ({s['bytes_spilled']} B spilled "
              f"over {s['pages_spilled']} pages), "
              f"{s['prefill_bytes_gathered']} B prefill-gathered")

    total = agree = 0
    for i in outs["fp"]:
        for a, b in zip(outs["fp"][i], outs["int8"][i]):
            total += 1
            agree += int(a == b)
    top1 = agree / max(total, 1)

    model_q = build_model(cfg, remat=False, use_kernels=True,
                          kv_dtype="int8")
    logit_err, probe_agree = _logit_probe(model, model_q, params, cfg)
    print(f"greedy top-1 agreement int8 vs fp: {top1:.3f} "
          f"({agree}/{total} positions; threshold "
          f"{AGREEMENT_THRESHOLD})")
    print(f"teacher-forced decode-logit probe: max abs err "
          f"{logit_err:.4f}, argmax agreement {probe_agree:.2f}")

    fp, q, qr, qm = (stats[k] for k in ("fp", "int8", "int8_ref",
                                        "int8_mesh"))
    itemsize_ratio = fp["bytes_per_page"] / max(q["bytes_per_page"], 1)
    spill_ratio = fp["bytes_spilled"] / max(q["bytes_spilled"], 1)
    gather_ratio = (qr["prefill_bytes_gathered"]
                    / max(q["prefill_bytes_gathered"], 1))
    print(f"bytes/page {fp['bytes_per_page']} -> {q['bytes_per_page']} "
          f"({itemsize_ratio:.0f}x), bytes spilled {fp['bytes_spilled']} "
          f"-> {q['bytes_spilled']} ({spill_ratio:.0f}x, "
          f"{fp['pages_spilled']} vs {q['pages_spilled']} pages), "
          f"prefill gather int8 kernel vs int8 ref: "
          f"{q['prefill_bytes_gathered']} vs "
          f"{qr['prefill_bytes_gathered']} B ({gather_ratio:.2f}x)")

    metrics = {
        "top1_agreement": float(top1),
        "agreement_threshold": AGREEMENT_THRESHOLD,
        "logit_max_abs_err": float(logit_err),
        "logit_probe_argmax_agreement": float(probe_agree),
        "bytes_per_page_fp": int(fp["bytes_per_page"]),
        "bytes_per_page_int8": int(q["bytes_per_page"]),
        "bytes_spilled_fp": int(fp["bytes_spilled"]),
        "bytes_spilled_int8": int(q["bytes_spilled"]),
        "pages_spilled_fp": int(fp["pages_spilled"]),
        "pages_spilled_int8": int(q["pages_spilled"]),
        "prefill_bytes_gathered_int8": int(q["prefill_bytes_gathered"]),
        "prefill_bytes_gathered_int8_ref": int(qr["prefill_bytes_gathered"]),
        "kernel_dispatches_int8": int(q["kernel_dispatches"]),
        "ref_path_dispatches_int8": int(q["ref_path_dispatches"]),
        "quant_dispatches_int8": int(q["quant_dispatches"]),
        "ref_path_dispatches_int8_mesh": int(qm["ref_path_dispatches"]),
        "kernel_dispatches_int8_mesh": int(qm["kernel_dispatches"]),
        "quant_dispatches_int8_mesh": int(qm["quant_dispatches"]),
        "mesh_devices": int(mesh.size),
        # gate booleans, evaluated here so run.py stays a thin reporter
        "kernels_live": bool(
            q["ref_path_dispatches"] == 0 and q["kernel_dispatches"] > 0
            and q["quant_dispatches"] > 0
            and qm["ref_path_dispatches"] == 0
            and qm["kernel_dispatches"] > 0 and qm["quant_dispatches"] > 0
        ),
        "token_identical_ref": bool(outs["int8"] == outs["int8_ref"]),
        "token_identical_mesh": bool(outs["int8"] == outs["int8_mesh"]),
        "bytes_halved": bool(
            q["bytes_per_page"] * 2 <= fp["bytes_per_page"]
            and q["bytes_per_page"] * round(itemsize_ratio)
            == fp["bytes_per_page"]
        ),
        "spill_halved": bool(
            fp["switches"] > 0
            and fp["pages_spilled"] == q["pages_spilled"]
            and q["bytes_spilled"] * round(itemsize_ratio)
            == fp["bytes_spilled"]
        ),
        "bytes_win": bool(
            q["prefill_bytes_gathered"] < qr["prefill_bytes_gathered"]
        ),
    }
    csv = [
        f"quant_top1_agreement,0,{top1:.4f}",
        f"quant_logit_max_abs_err,0,{logit_err:.5f}",
        f"quant_bytes_per_page_fp,0,{fp['bytes_per_page']}",
        f"quant_bytes_per_page_int8,0,{q['bytes_per_page']}",
        f"quant_bytes_spilled_fp,0,{fp['bytes_spilled']}",
        f"quant_bytes_spilled_int8,0,{q['bytes_spilled']}",
        f"quant_prefill_bytes_int8_kernel,0,{q['prefill_bytes_gathered']}",
        f"quant_prefill_bytes_int8_ref,0,{qr['prefill_bytes_gathered']}",
        f"quant_ref_path_dispatches_int8,0,{q['ref_path_dispatches']}",
        f"quant_dispatches_int8,0,{q['quant_dispatches']}",
    ]
    return csv, metrics


def main() -> list[str]:
    csv, _ = run()
    return csv


if __name__ == "__main__":
    main()
