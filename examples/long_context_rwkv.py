"""Long-context decode with an attention-free architecture (rwkv6 family).

The ``long_500k`` input shape is only admissible for sub-quadratic
architectures (DESIGN.md §4).  This example shows WHY with the reduced
rwkv6 config: the recurrent state is O(1) in context length — we prefill a
prompt, then decode with a context counter wound to half a million tokens,
and the state size / step cost never change.  For contrast, the same is
impossible for the dense families whose KV grows linearly (their cells skip
long_500k in the dry-run).

Run:  PYTHONPATH=src python examples/long_context_rwkv.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def main() -> None:
    cfg = get_config("rwkv6-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    B = 2

    state = model.init_state(B)
    state_bytes = sum(
        np.asarray(x).nbytes for x in jax.tree.leaves(state)
    )
    print(f"recurrent state: {state_bytes/1024:.1f} KiB for batch {B} "
          f"(constant in context length)")

    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 32), 0,
                                cfg.vocab_size)
    logits, state = model.prefill(
        params, tokens, jnp.full((B,), 32, jnp.int32), state
    )
    print(f"prefilled 32 tokens; seq_lens = {state.seq_lens}")

    # pretend the model has been decoding for a very long time: the state
    # is the ONLY thing carried — wind the clock to 524288 - 4
    state = state._replace(
        seq_lens=jnp.full((B,), 524_288 - 4, jnp.int32)
    )
    times = []
    tok = jnp.argmax(logits, axis=-1)
    for i in range(4):
        t0 = time.perf_counter()
        logits, state = model.decode_step(params, tok, state)
        logits.block_until_ready()
        times.append(time.perf_counter() - t0)
        tok = jnp.argmax(logits, axis=-1)
    print(f"decode at ~524k context: seq_lens = {state.seq_lens}")
    print(f"per-step wall (CPU): {[f'{t*1e3:.1f}ms' for t in times]} "
          f"- flat, independent of context")
    new_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(state))
    assert new_bytes == state_bytes, "state grew with context!"
    print("state size unchanged - the sub-quadratic property the "
          "long_500k cell relies on")


if __name__ == "__main__":
    main()
